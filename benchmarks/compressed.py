"""Compressed-band two-band verification sweep (DESIGN.md §10).

For d ∈ {96, 256, 768} and p ∈ {0.5, 0.8, 1.25, 2.0} runs the same
ANNS-U-Lp workload with the int8 compressed storage band ON and with the
legacy full-dimension path (abandon=False — the bitwise reference the
two-band scan reproduces) at matched (t, kappa, tau) and records:

  * screen_out — fraction of scored candidates whose f32 row gather was
    *avoided* because the certified compressed lower bound already
    exceeded the running k-th best (1 - n_f32_rows_frac, N_p-weighted);
  * f32_bytes_reduction — 1 / n_f32_rows_frac: how many times fewer f32
    bytes the verification stage gathered from the corpus;
  * bytes_ratio — total bytes touched (int8 band reads + surviving f32
    reads) relative to the uncompressed path: f32_frac + band_frac / 4.
    < 1.0 means the screen pays for itself in raw bandwidth, not just
    in f32 gathers;
  * ids_equal — the two-band path must return *bitwise identical* ids
    (and distances) to the uncompressed path: screening is certified,
    never lossy;
  * n_dim_frac — the early-abandon dimension fraction of the surviving
    f32 rescans, for cross-reference against BENCH_verify.

The p = 2.0 row is the honest null: p equals the G2 base metric, the
search takes the exact-base skip (no verification at all, N_p = 0), so
the band never engages — screen_out = 0 and bytes_ratio = 1 there by
construction, not by regression.

The acceptance criterion (ISSUE 9) is >= 2x f32-byte reduction at
p ∈ {0.5, 0.8} with ids_equal everywhere. Like BENCH_verify, the
machine-portable byte/row ratios are what CI gates; wall-clock on this
CPU container reflects compute-then-mask reference semantics, not the
HBM gather the screen saves on a TPU.

  PYTHONPATH=src python -m benchmarks.run --only compressed [--quick]
"""

from __future__ import annotations

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.verify import _index
from repro.core.uhnsw import UHNSWParams

P_GRID = (0.5, 0.8, 1.25, 2.0)
D_GRID = (96, 256, 768)
K = 10


def _weighted(frac, n_p, empty: float):
    """N_p-weighted mean of a per-query fraction (matches serving stats).

    `empty` is the value when no candidates were verified at all (the
    base-metric skip: p == base graph metric, n_p == 0 everywhere) — 1.0
    for the f32/dim fractions, 0.0 for band traffic.
    """
    frac = np.asarray(frac, dtype=np.float64)
    n_p = np.asarray(n_p, dtype=np.float64)
    tot = float(n_p.sum())
    if frac.ndim == 0:
        return float(frac)
    return float((frac * n_p).sum() / tot) if tot else empty


def run(quick: bool = False):
    n = 1500 if quick else 4000
    nq = 16 if quick else 32
    # same hardware-shaped verification as BENCH_verify (lane-width kappa);
    # the band rides on top of the abandon machinery, so abandon stays on
    params = UHNSWParams(t=300, kappa=128, tau=0.92, abandon=True,
                         compressed_band=True)

    rows = []
    for d in D_GRID:
        idx, data, queries = _index(d, n, nq, params)
        Q = jnp.asarray(queries)
        band = idx.compressed_band()   # build once per d, reused across p
        band_bytes = band.nbytes()
        f32_bytes = data.size * 4
        for p in P_GRID:
            idx.params = replace(params, compressed_band=True)
            t0 = time.time()
            ids_c, dists_c, stats_c = idx.search(Q, p, K)
            jax.block_until_ready(ids_c)
            ms_c = (time.time() - t0) / Q.shape[0] * 1e3
            # bitwise reference: the legacy full-dimension path (the two-
            # band scan is constructed to reproduce ITS state trajectory;
            # the abandon scan's transposed reduction differs by <= 1 ulp
            # in dists, see tests/test_verify_abandon.py)
            idx.params = replace(params, compressed_band=False,
                                 abandon=False)
            t0 = time.time()
            ids_f, dists_f, stats_f = idx.search(Q, p, K)
            jax.block_until_ready(ids_f)
            ms_f = (time.time() - t0) / Q.shape[0] * 1e3

            n_p = stats_c.n_p
            f32_frac = _weighted(stats_c.n_f32_rows_frac, n_p, empty=1.0)
            band_frac = _weighted(stats_c.n_band_frac, n_p, empty=0.0)
            ids_equal = bool(np.array_equal(np.asarray(ids_c),
                                            np.asarray(ids_f))
                             and np.array_equal(np.asarray(dists_c),
                                                np.asarray(dists_f)))
            row = {
                "bench": "compressed", "dataset": f"decay-d{d}", "d": d,
                "n": n, "p": p, "k": K, "t": params.t,
                "kappa": params.kappa, "tau": params.tau,
                # f32 rows actually gathered per scored candidate
                "f32_rows_frac": round(f32_frac, 4),
                "screen_out": round(1.0 - f32_frac, 4),
                "f32_bytes_reduction": round(1.0 / max(f32_frac, 1e-9), 2),
                # int8 band dims scanned per scored candidate dim
                "band_scan_frac": round(band_frac, 4),
                "bytes_ratio": round(f32_frac + band_frac / 4.0, 4),
                "ids_equal": ids_equal,
                "n_dim_frac": round(
                    _weighted(stats_c.n_dim_frac, n_p, empty=1.0), 4),
                "band_bytes_over_f32": round(band_bytes / f32_bytes, 4),
                "ms_per_query_band": round(ms_c, 3),
                "ms_per_query_full": round(ms_f, 3),
            }
            rows.append(row)
            print(f"  d={d} p={p}: screen_out={row['screen_out']:.3f} "
                  f"f32x{row['f32_bytes_reduction']:.1f} "
                  f"bytes_ratio={row['bytes_ratio']:.3f} "
                  f"ids_equal={ids_equal}", flush=True)
        idx.params = params

    # acceptance (ISSUE 9): >= 2x f32-byte reduction for the cheap-band
    # family p in {0.5, 0.8}, ids bitwise-identical everywhere
    gate = [r for r in rows if r["p"] in (0.5, 0.8)]
    ok = (all(r["f32_bytes_reduction"] >= 2.0 for r in gate)
          and all(r["ids_equal"] for r in rows))
    print(f"acceptance (f32_bytes_reduction >= 2.0 at p in {{0.5, 0.8}}, "
          f"ids identical): {'PASS' if ok else 'FAIL'}")
    return rows


if __name__ == "__main__":
    run()
